"""Pallas TPU kernel: VMEM-resident cyclic coordinate-minimization epochs.

The SAIF inner loop runs K cyclic soft-threshold sweeps over the active block
A (n x k). k is small (<= ~1k) so the whole block, the residual, and the
coefficients fit in VMEM; after the initial HBM->VMEM load, an epoch performs
ZERO HBM traffic — the TPU-native answer to the paper's tight C inner loop.

Least-squares form (residual r = y - A beta maintained incrementally):
    g      = a_j^T r
    b_new  = S(b_j + g / ||a_j||^2,  lam / ||a_j||^2)
    r     += (b_j - b_new) a_j

The cyclic j-loop is inherently sequential (that's what "cyclic CM" means and
what Lemma 1's rate analyzes); the n-dimension vectorizes across the 8x128
VPU lanes. Grid = (1,): a single kernel instance owns the whole sweep.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cm_kernel(a_ref, y_ref, beta_in_ref, colsq_ref, mask_ref, lam_ref,
               beta_ref, r_ref, *, n_epochs: int, k: int):
    # beta_ref is the output aliased onto beta_in_ref (input_output_aliases),
    # so it already holds the inbound coefficients.
    del beta_in_ref
    # residual r = y - A beta  (beta_ref holds the inbound coefficients;
    # we compute r once from scratch, then maintain it incrementally).
    a = a_ref[...]                       # (n, k) — VMEM resident
    beta0 = beta_ref[...]                # (k,)
    r_ref[...] = y_ref[...] - jnp.dot(a, beta0,
                                      preferred_element_type=jnp.float32)
    lam = lam_ref[0]

    def coord_step(j, _):
        aj = a[:, j]                     # static-unroll-free dynamic column
        csq = jnp.maximum(colsq_ref[j], 1e-30)
        g = jnp.dot(aj, r_ref[...], preferred_element_type=jnp.float32)
        bj = beta_ref[j]
        u = bj + g / csq
        t = lam / csq
        b_new = jnp.sign(u) * jnp.maximum(jnp.abs(u) - t, 0.0)
        b_new = jnp.where(mask_ref[j], b_new, 0.0)
        r_ref[...] += (bj - b_new) * aj
        beta_ref[j] = b_new
        return 0

    def epoch(_, carry):
        return jax.lax.fori_loop(0, k, coord_step, carry)

    jax.lax.fori_loop(0, n_epochs, epoch, 0)


@functools.partial(jax.jit, static_argnames=("n_epochs", "interpret"))
def cm_epochs_pallas(A, y, beta, col_sq, mask, lam, *,
                     n_epochs: int = 1, interpret: bool = True):
    """K cyclic CM sweeps on the active block. Returns (beta, residual).

    A: (n, k) f32 — must fit VMEM (checked: n*k*4 <= 12 MB).
    """
    n, k = A.shape
    assert n * k * 4 <= 12 * 2**20, (
        f"active block {n}x{k} exceeds the VMEM budget; shrink k_max or "
        f"shard the sample dimension (see DESIGN.md §5)")
    kernel = functools.partial(_cm_kernel, n_epochs=n_epochs, k=k)
    beta_out, r_out = pl.pallas_call(
        kernel,
        in_specs=[
            pl.BlockSpec(A.shape, lambda: (0, 0)),   # A
            pl.BlockSpec((n,), lambda: (0,)),         # y
            pl.BlockSpec((k,), lambda: (0,)),         # beta (aliased)
            pl.BlockSpec((k,), lambda: (0,)),         # col_sq
            pl.BlockSpec((k,), lambda: (0,)),         # mask
            pl.BlockSpec((1,), lambda: (0,)),         # lam
        ],
        out_specs=[
            pl.BlockSpec((k,), lambda: (0,)),
            pl.BlockSpec((n,), lambda: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        input_output_aliases={2: 0},   # beta is updated in place
        interpret=interpret,
    )(A.astype(jnp.float32), y.astype(jnp.float32),
      beta.astype(jnp.float32), col_sq.astype(jnp.float32),
      mask, jnp.asarray(lam, jnp.float32).reshape(1))
    return beta_out, r_out
