"""Pallas TPU kernels: VMEM-resident cyclic coordinate-minimization bursts.

The SAIF inner loop runs K cyclic soft-threshold sweeps over the active block
A (n x k). k is small (<= ~1k) so the whole block, the model vector, and the
coefficients fit in VMEM; after the initial HBM->VMEM load, an epoch performs
ZERO HBM traffic — the TPU-native answer to the paper's tight C inner loop.

Two entry points:

``cm_epochs_pallas`` — the original least-squares epoch kernel (residual
r = y - A beta maintained incrementally), kept as the simple oracle-tested
form:
    g      = a_j^T r
    b_new  = S(b_j + g / ||a_j||^2,  lam / ||a_j||^2)
    r     += (b_j - b_new) a_j

``cm_burst_pallas`` — the production inner-solver backend
(``repro.core.inner_backend``, DESIGN.md §6). Generalizations over the epoch
kernel:
  * **general alpha-smooth losses** via the prox-Newton-majorized step
    (exactly ``core/cm.py::_coordinate_step``): the model vector z = A beta
    is VMEM-resident and updated rank-1; the per-step gradient f'(z) is an
    elementwise VPU pass;
  * **compact sweeps**: only the ``count`` live slots listed first in
    ``order`` are visited, and both ``count`` and the epoch count ``n_epochs``
    are *traced* scalars (read from VMEM inside the kernel) so one compiled
    kernel serves every outer step of the solver — ADD-phase and polish
    bursts alike;
  * **fused dual point + duality gap**: after the burst the kernel computes
    the feasible dual point (Lemma 2 scaling, with the LS-specific tau*
    projection) and the sub-problem duality gap from the VMEM-resident
    state, so one kernel call covers the whole "CM burst + gap" of a SAIF
    outer step — no second HBM pass over the active block;
  * **dtype-generic**: computes in A.dtype (f32 on TPU; f64 under the
    interpreter, where the x64 test suite needs full-precision gaps), and
    ``interpret=None`` auto-detects the backend exactly like the screening
    kernels.

The cyclic j-loop is inherently sequential (that's what "cyclic CM" means and
what Lemma 1's rate analyzes); the n-dimension vectorizes across the 8x128
VPU lanes. Grid = (1,): a single kernel instance owns the whole burst.
``cm_vmem_ok`` is the block "autotuner" for this kernel family: with no free
tiling axis the only decision is whether the burst fits the VMEM budget at
all — the inner-backend resolver uses it to gate the pallas backend.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VMEM budget for the (n, k) active block: leave ~4 MB of the ~16 MB for the
# (n,)-shaped vectors (y, z, theta), the (k,)-shaped state and headroom.
CM_VMEM_BUDGET_BYTES = 12 * 2**20


def cm_vmem_ok(n: int, k: int, itemsize: int = 4, batch: int = 1) -> bool:
    """Does a (n, k) CM burst fit the VMEM budget? (block-fit autotune).

    ``batch`` > 1 is the problem-gridded fleet kernel: each grid step owns
    ONE problem's (n, k) block, but the pipeline double-buffers the next
    problem's block while the current burst runs, so the fleet budget is
    two problems' working sets — independent of the fleet size B beyond
    that. This is the "batched budget" the inner-backend resolver consults
    for fleets (DESIGN.md §8).
    """
    per_problem = (n * k + 4 * n + 6 * k) * itemsize
    return per_problem * (2 if batch > 1 else 1) <= CM_VMEM_BUDGET_BYTES


def _cm_kernel(a_ref, y_ref, beta_in_ref, colsq_ref, mask_ref, lam_ref,
               beta_ref, r_ref, *, n_epochs: int, k: int):
    # beta_ref is the output aliased onto beta_in_ref (input_output_aliases),
    # so it already holds the inbound coefficients.
    del beta_in_ref
    # residual r = y - A beta  (beta_ref holds the inbound coefficients;
    # we compute r once from scratch, then maintain it incrementally).
    a = a_ref[...]                       # (n, k) — VMEM resident
    beta0 = beta_ref[...]                # (k,)
    r_ref[...] = y_ref[...] - jnp.dot(a, beta0,
                                      preferred_element_type=jnp.float32)
    lam = lam_ref[0]

    def coord_step(j, _):
        aj = a[:, j]                     # static-unroll-free dynamic column
        csq = jnp.maximum(colsq_ref[j], 1e-30)
        g = jnp.dot(aj, r_ref[...], preferred_element_type=jnp.float32)
        bj = beta_ref[j]
        u = bj + g / csq
        t = lam / csq
        b_new = jnp.sign(u) * jnp.maximum(jnp.abs(u) - t, 0.0)
        b_new = jnp.where(mask_ref[j], b_new, 0.0)
        r_ref[...] += (bj - b_new) * aj
        beta_ref[j] = b_new
        return 0

    def epoch(_, carry):
        return jax.lax.fori_loop(0, k, coord_step, carry)

    jax.lax.fori_loop(0, n_epochs, epoch, 0)


@functools.partial(jax.jit, static_argnames=("n_epochs", "interpret"))
def cm_epochs_pallas(A, y, beta, col_sq, mask, lam, *,
                     n_epochs: int = 1, interpret: bool = True):
    """K cyclic CM sweeps on the active block. Returns (beta, residual).

    A: (n, k) f32 — must fit VMEM (checked: n*k*4 <= 12 MB).
    """
    n, k = A.shape
    assert n * k * 4 <= CM_VMEM_BUDGET_BYTES, (
        f"active block {n}x{k} exceeds the VMEM budget; shrink k_max or "
        f"shard the sample dimension (see DESIGN.md §5)")
    kernel = functools.partial(_cm_kernel, n_epochs=n_epochs, k=k)
    beta_out, r_out = pl.pallas_call(
        kernel,
        in_specs=[
            pl.BlockSpec(A.shape, lambda: (0, 0)),   # A
            pl.BlockSpec((n,), lambda: (0,)),         # y
            pl.BlockSpec((k,), lambda: (0,)),         # beta (aliased)
            pl.BlockSpec((k,), lambda: (0,)),         # col_sq
            pl.BlockSpec((k,), lambda: (0,)),         # mask
            pl.BlockSpec((1,), lambda: (0,)),         # lam
        ],
        out_specs=[
            pl.BlockSpec((k,), lambda: (0,)),
            pl.BlockSpec((n,), lambda: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        input_output_aliases={2: 0},   # beta is updated in place
        interpret=interpret,
    )(A.astype(jnp.float32), y.astype(jnp.float32),
      beta.astype(jnp.float32), col_sq.astype(jnp.float32),
      mask, jnp.asarray(lam, jnp.float32).reshape(1))
    return beta_out, r_out


# --------------------------------------------------------------------------
# fused burst kernel: compact prox-Newton epochs + dual point + duality gap
# --------------------------------------------------------------------------

def _cm_burst_kernel(a_ref, y_ref, beta_in_ref, colsq_ref, mask_ref,
                     order_ref, pen_ref, lam_ref, nep_ref, cnt_ref,
                     beta_ref, z_ref, theta_ref, gap_ref, *, loss,
                     has_unpen: bool):
    from repro.core.duality import polish_unpen
    del beta_in_ref                     # aliased onto beta_ref
    a = a_ref[...]                      # (n, k) — VMEM resident, dead cols 0
    y = y_ref[...]
    lam = lam_ref[0]
    alpha = loss.smoothness             # static per-loss constant
    dt = a.dtype
    z_ref[...] = jnp.dot(a, beta_ref[...], preferred_element_type=dt)

    def coord_step(jj, _):
        j = order_ref[jj]               # compact sweep: live slots only
        aj = a[:, j]
        lj = jnp.maximum(alpha * colsq_ref[j], 1e-30)
        g = jnp.dot(aj, loss.grad(z_ref[...], y),
                    preferred_element_type=dt)
        bj = beta_ref[j]
        u = bj - g / lj
        t = lam * pen_ref[j] / lj       # pen=0: exact unpenalized step
        b_new = jnp.sign(u) * jnp.maximum(jnp.abs(u) - t, 0.0)
        b_new = jnp.where(mask_ref[j], b_new, 0.0)
        z_ref[...] += (b_new - bj) * aj
        beta_ref[j] = b_new
        return 0

    def epoch(_, carry):
        return jax.lax.fori_loop(0, cnt_ref[0], coord_step, carry)

    jax.lax.fori_loop(0, nep_ref[0], epoch, 0)

    # ---- fused dual-point / duality-gap tail (still VMEM-resident) -------
    beta = beta_ref[...]
    pen = pen_ref[...]
    z = jnp.dot(a, beta, preferred_element_type=dt)   # fresh, drift-free
    if has_unpen:
        # b's column — the one live slot with pen = 0 — shared by the
        # Newton polish and the equality projection below
        w = jnp.where(mask_ref[...], 1.0 - pen, 0.0).astype(dt)
        ab = jnp.dot(a, w, preferred_element_type=dt)   # (n,)
        if loss.name != "least_squares":
            # General loss: Newton-polish the unpenalized coordinate to
            # stationarity before forming the dual point, so x_b^T f'(z)
            # ~ 0 and the equality projection is a benign ~0 correction
            # (duality.polish_unpen — the same pure-jax fold runs inside
            # the kernel, DESIGN.md §7).
            b_cur = jnp.dot(beta, w, preferred_element_type=dt)
            b_new, z = polish_unpen(loss, ab, y, z, b_cur)
            beta = jnp.where(w > 0.5, b_new, beta)
            beta_ref[...] = beta
    z_ref[...] = z
    hat = -loss.grad(z, y) / lam                      # unscaled dual point
    if has_unpen:
        # Thm-7 equality constraint x_b^T theta = 0: project hat onto the
        # hyperplane before scaling (duality.feasible_dual, DESIGN.md §7)
        sq_b = jnp.dot(ab, ab, preferred_element_type=dt)
        hat = hat - ab * (jnp.dot(ab, hat, preferred_element_type=dt)
                          / jnp.maximum(sq_b, 1e-30))
    corr = jnp.dot(hat, a, preferred_element_type=dt)  # (k,); dead cols -> 0
    max_corr = jnp.max(jnp.abs(corr) * pen)            # penalized cols only
    if loss.name == "least_squares":
        # DPP-style optimal scaling (duality.feasible_dual, LS branch)
        bound = 1.0 / jnp.maximum(max_corr, 1e-30)
        sq = jnp.sum(hat * hat)
        tau_star = jnp.dot(y, hat) / (lam * jnp.maximum(sq, 1e-30))
        tau = jnp.clip(tau_star, -bound, bound)
        tau = jnp.where(jnp.isfinite(tau), tau,
                        1.0 / jnp.maximum(max_corr, 1.0))
        theta = tau * hat
    else:
        theta = hat / jnp.maximum(max_corr, 1.0)
        theta = -loss.dual_clip(-lam * theta, y) / lam
    theta_ref[...] = theta
    p_val = jnp.sum(loss.value(z, y)) + lam * jnp.sum(pen * jnp.abs(beta))
    d_val = -jnp.sum(loss.conj(-lam * theta, y))
    gap_ref[0] = p_val - d_val


# --------------------------------------------------------------------------
# problem-gridded fleet burst kernel (batch engine, DESIGN.md §8)
# --------------------------------------------------------------------------

def _cm_burst_batch_kernel(a_ref, y_ref, beta_in_ref, colsq_ref, mask_ref,
                           order_ref, lam_ref, nep_ref, cnt_ref,
                           beta_ref, z_ref, theta_ref, gap_ref, *, loss):
    """One grid step = one problem's whole "CM burst + dual + gap".

    The body is :func:`_cm_burst_kernel` without the unpenalized-slot
    machinery (fleets are plain LASSO, §8), reading this problem's blocks
    (leading length-1 problem dim). Per-problem traced epoch/live counts
    arrive through the (1,)-blocked ``nep``/``cnt`` operands, so a finished
    problem's grid step runs a zero-trip burst — only the initial z matmul
    and the dual/gap tail touch the VPU for it.
    """
    del beta_in_ref                     # aliased onto beta_ref
    a = a_ref[0]                        # (n, k) this problem's active block
    y = y_ref[0, :]
    lam = lam_ref[0]
    alpha = loss.smoothness
    dt = a.dtype
    z_ref[0, :] = jnp.dot(a, beta_ref[0, :], preferred_element_type=dt)

    def coord_step(jj, _):
        j = order_ref[0, jj]
        aj = a[:, j]
        lj = jnp.maximum(alpha * colsq_ref[0, j], 1e-30)
        g = jnp.dot(aj, loss.grad(z_ref[0, :], y),
                    preferred_element_type=dt)
        bj = beta_ref[0, j]
        u = bj - g / lj
        t = lam / lj
        b_new = jnp.sign(u) * jnp.maximum(jnp.abs(u) - t, 0.0)
        b_new = jnp.where(mask_ref[0, j], b_new, 0.0)
        z_ref[0, :] += (b_new - bj) * aj
        beta_ref[0, j] = b_new
        return 0

    def epoch(_, carry):
        return jax.lax.fori_loop(0, cnt_ref[0], coord_step, carry)

    jax.lax.fori_loop(0, nep_ref[0], epoch, 0)

    # ---- fused dual-point / duality-gap tail (VMEM-resident) -------------
    beta = beta_ref[0, :]
    z = jnp.dot(a, beta, preferred_element_type=dt)
    z_ref[0, :] = z
    hat = -loss.grad(z, y) / lam
    corr = jnp.dot(hat, a, preferred_element_type=dt)
    max_corr = jnp.max(jnp.abs(corr))
    if loss.name == "least_squares":
        bound = 1.0 / jnp.maximum(max_corr, 1e-30)
        sq = jnp.sum(hat * hat)
        tau_star = jnp.dot(y, hat) / (lam * jnp.maximum(sq, 1e-30))
        tau = jnp.clip(tau_star, -bound, bound)
        tau = jnp.where(jnp.isfinite(tau), tau,
                        1.0 / jnp.maximum(max_corr, 1.0))
        theta = tau * hat
    else:
        theta = hat / jnp.maximum(max_corr, 1.0)
        theta = -loss.dual_clip(-lam * theta, y) / lam
    theta_ref[0, :] = theta
    p_val = jnp.sum(loss.value(z, y)) + lam * jnp.sum(jnp.abs(beta))
    d_val = -jnp.sum(loss.conj(-lam * theta, y))
    gap_ref[0] = p_val - d_val


@functools.partial(jax.jit, static_argnames=("loss_name", "interpret"))
def cm_burst_batch_pallas(A, Y, beta, col_sq, mask, order, lam, n_epochs,
                          count, *, loss_name: str = "least_squares",
                          interpret: bool | None = None):
    """Fleet "CM burst + gap": grid axis over problems, one launch for B.

    Args mirror :func:`cm_burst_pallas` with a leading problem axis:
    A (B, n, k) per-problem active blocks, Y (B, n), beta/col_sq/mask/order
    (B, k), lam/n_epochs/count (B,). Each grid step owns one problem's
    burst end-to-end in VMEM; the double-buffered fleet budget is checked
    by ``cm_vmem_ok(..., batch=B)``.
    Returns (beta (B, k), z (B, n), theta (B, n), gap (B,)).
    """
    from repro.core.losses import get_loss

    loss = get_loss(loss_name)
    b, n, k = A.shape
    dt = A.dtype
    assert cm_vmem_ok(n, k, dt.itemsize, batch=b), (
        f"a fleet of {b} {n}x{k} active blocks ({dt}) exceeds the "
        f"double-buffered VMEM budget; shrink k_max or shard the sample "
        f"dimension (see DESIGN.md §5/§8)")
    if interpret is None:
        from repro.kernels.screen.screen import default_interpret
        interpret = default_interpret()
    kernel = functools.partial(_cm_burst_batch_kernel, loss=loss)
    blk = pl.BlockSpec((1, n, k), lambda bb: (bb, 0, 0))
    vec_k = pl.BlockSpec((1, k), lambda bb: (bb, 0))
    vec_n = pl.BlockSpec((1, n), lambda bb: (bb, 0))
    one = pl.BlockSpec((1,), lambda bb: (bb,))
    beta_out, z_out, theta_out, gap_out = pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[
            blk,                                      # A
            vec_n,                                    # Y
            vec_k,                                    # beta (aliased)
            vec_k,                                    # col_sq
            vec_k,                                    # mask
            vec_k,                                    # order
            one,                                      # lam
            one,                                      # n_epochs
            one,                                      # count
        ],
        out_specs=[vec_k, vec_n, vec_n, one],
        out_shape=[
            jax.ShapeDtypeStruct((b, k), dt),         # beta
            jax.ShapeDtypeStruct((b, n), dt),         # z
            jax.ShapeDtypeStruct((b, n), dt),         # theta
            jax.ShapeDtypeStruct((b,), dt),           # gap
        ],
        input_output_aliases={2: 0},                  # beta updated in place
        interpret=interpret,
    )(A, Y.astype(dt), beta.astype(dt), col_sq.astype(dt), mask,
      order.astype(jnp.int32), jnp.asarray(lam, dt),
      jnp.asarray(n_epochs, jnp.int32), jnp.asarray(count, jnp.int32))
    return beta_out, z_out, theta_out, gap_out


@functools.partial(jax.jit, static_argnames=("loss_name", "interpret"))
def cm_burst_pallas(A, y, beta, col_sq, mask, order, lam, n_epochs, count,
                    pen=None, *, loss_name: str = "least_squares",
                    interpret: bool | None = None):
    """One fused "CM burst + gap" call on the active block.

    Args:
      A:        (n, k) active design block, dead columns zeroed. Computation
                runs in A.dtype (f32 on TPU; f64 under the interpreter).
      beta:     (k,) inbound coefficients (0 on dead slots).
      order:    (k,) int32 slot permutation, the ``count`` live slots first.
      n_epochs: traced sweep count (the solver batches ADD vs polish bursts
                through this one compiled kernel).
      count:    traced live-slot count.
      pen:      (k,) optional per-slot l1 weight: 0 marks the always-resident
                unpenalized slot (fused LASSO's ``b``, DESIGN.md §7), which
                also switches the dual tail to the Thm-7 equality-projected
                scaling. None = all penalized (the plain-LASSO fast path).
    Returns (beta, z, theta, gap): the updated coefficients, the fresh model
    vector z = A beta, the feasible dual point, and the sub-problem duality
    gap — everything a SAIF outer step needs from the inner solver.
    """
    from repro.core.losses import get_loss

    loss = get_loss(loss_name)
    n, k = A.shape
    dt = A.dtype
    assert cm_vmem_ok(n, k, dt.itemsize), (
        f"active block {n}x{k} ({dt}) exceeds the VMEM budget; shrink "
        f"k_max or shard the sample dimension (see DESIGN.md §5/§6)")
    if interpret is None:
        from repro.kernels.screen.screen import default_interpret
        interpret = default_interpret()
    has_unpen = pen is not None
    if pen is None:
        pen = jnp.ones((k,), dt)
    kernel = functools.partial(_cm_burst_kernel, loss=loss,
                               has_unpen=has_unpen)
    vec_k = pl.BlockSpec((k,), lambda: (0,))
    vec_n = pl.BlockSpec((n,), lambda: (0,))
    one = pl.BlockSpec((1,), lambda: (0,))
    beta_out, z_out, theta_out, gap_out = pl.pallas_call(
        kernel,
        in_specs=[
            pl.BlockSpec(A.shape, lambda: (0, 0)),    # A
            vec_n,                                    # y
            vec_k,                                    # beta (aliased)
            vec_k,                                    # col_sq
            vec_k,                                    # mask
            vec_k,                                    # order
            vec_k,                                    # pen
            one,                                      # lam
            one,                                      # n_epochs
            one,                                      # count
        ],
        out_specs=[vec_k, vec_n, vec_n, one],
        out_shape=[
            jax.ShapeDtypeStruct((k,), dt),           # beta
            jax.ShapeDtypeStruct((n,), dt),           # z
            jax.ShapeDtypeStruct((n,), dt),           # theta
            jax.ShapeDtypeStruct((1,), dt),           # gap
        ],
        input_output_aliases={2: 0},                  # beta updated in place
        interpret=interpret,
    )(A, y.astype(dt), beta.astype(dt), col_sq.astype(dt), mask,
      order.astype(jnp.int32), pen.astype(dt),
      jnp.asarray(lam, dt).reshape(1),
      jnp.asarray(n_epochs, jnp.int32).reshape(1),
      jnp.asarray(count, jnp.int32).reshape(1))
    return beta_out, z_out, theta_out, gap_out[0]
