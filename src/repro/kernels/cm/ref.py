"""Pure-jnp oracle for the CM-epoch kernel (least squares)."""
import jax
import jax.numpy as jnp


def cm_epochs_ref(A, y, beta, col_sq, mask, lam, n_epochs=1):
    """Reference cyclic CM sweeps; mirrors kernels/cm/cm.py exactly."""
    r = y - A @ beta

    def coord_step(j, carry):
        beta, r = carry
        aj = A[:, j]
        csq = jnp.maximum(col_sq[j], 1e-30)
        g = jnp.dot(aj, r)
        u = beta[j] + g / csq
        t = lam / csq
        b_new = jnp.sign(u) * jnp.maximum(jnp.abs(u) - t, 0.0)
        b_new = jnp.where(mask[j], b_new, 0.0)
        r = r + (beta[j] - b_new) * aj
        beta = beta.at[j].set(b_new)
        return beta, r

    def epoch(_, carry):
        return jax.lax.fori_loop(0, beta.shape[0], coord_step, carry)

    return jax.lax.fori_loop(0, n_epochs, epoch, (beta, r))
