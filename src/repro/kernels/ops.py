"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to compiled Mosaic on a TPU backend and interpreter
fallback everywhere else (this container is CPU-only; the Pallas interpreter
executes the kernel body in Python for correctness validation). Block shapes
default to the ``autotune_screen_blocks`` choice for the problem shape.
"""
from __future__ import annotations

import jax

from repro.kernels.cm.cm import (CM_VMEM_BUDGET_BYTES, cm_burst_pallas,
                                 cm_epochs_pallas, cm_vmem_ok)
from repro.kernels.cm.ref import cm_epochs_ref
from repro.kernels.fused.fused import (autotune_chain_block,
                                       chain_suffix_sums_pallas,
                                       chain_suffix_sums_ref)
from repro.kernels.screen.ref import (screen_fused_ref, screen_scores_ref,
                                      ub_histogram_ref)
from repro.kernels.screen.screen import (autotune_screen_blocks,
                                         default_interpret,
                                         screen_fused_pallas,
                                         screen_scores_pallas,
                                         ub_histogram_pallas)


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def screen_scores(X, theta, col_norm, r, *, bn=None, bp=None,
                  interpret: bool | None = None):
    """SAIF screening scan: (score, ub, lb) per feature."""
    return screen_scores_pallas(X, theta, col_norm, r, bn=bn, bp=bp,
                                interpret=interpret)


def screen_fused(X, theta, col_norm, active, r, *, h, bn=None, bp=None,
                 interpret: bool | None = None):
    """Fused ADD-phase scan: masked (score, ub, lb) + tile top-h + tile max."""
    return screen_fused_pallas(X, theta, col_norm, active, r, h=h,
                               bn=bn, bp=bp, interpret=interpret)


def ub_histogram(ub, lb_sorted, *, bp=None, interpret: bool | None = None):
    """Violation-count histogram of ub against sorted candidate bounds."""
    return ub_histogram_pallas(ub, lb_sorted, bp=bp, interpret=interpret)


def cm_epochs(A, y, beta, col_sq, mask, lam, *, n_epochs=1,
              interpret: bool | None = None):
    """VMEM-resident cyclic CM sweeps (least squares)."""
    if interpret is None:
        interpret = not on_tpu()
    return cm_epochs_pallas(A, y, beta, col_sq, mask, lam,
                            n_epochs=n_epochs, interpret=interpret)


def cm_burst(A, y, beta, col_sq, mask, order, lam, n_epochs, count,
             pen=None, *, loss_name="least_squares",
             interpret: bool | None = None):
    """Fused CM burst + dual point + duality gap (general smooth losses)."""
    return cm_burst_pallas(A, y, beta, col_sq, mask, order, lam, n_epochs,
                           count, pen=pen, loss_name=loss_name,
                           interpret=interpret)


def chain_suffix_sums(X, *, bp=None, interpret: bool | None = None):
    """Chain fused-LASSO column transform (suffix sums of design columns)."""
    return chain_suffix_sums_pallas(X, bp=bp, interpret=interpret)


__all__ = ["screen_scores", "screen_fused", "ub_histogram", "cm_epochs",
           "cm_burst", "cm_burst_pallas", "cm_vmem_ok",
           "chain_suffix_sums", "chain_suffix_sums_pallas",
           "chain_suffix_sums_ref", "autotune_chain_block",
           "CM_VMEM_BUDGET_BYTES",
           "screen_scores_ref", "screen_fused_ref", "ub_histogram_ref",
           "cm_epochs_ref", "on_tpu", "autotune_screen_blocks",
           "default_interpret"]
