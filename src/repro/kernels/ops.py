"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU (this container is CPU-only; the
Pallas interpreter executes the kernel body in Python for correctness
validation). On a real TPU backend the same call sites compile to Mosaic.
"""
from __future__ import annotations

import jax

from repro.kernels.cm.cm import cm_epochs_pallas
from repro.kernels.cm.ref import cm_epochs_ref
from repro.kernels.screen.ref import screen_scores_ref
from repro.kernels.screen.screen import screen_scores_pallas


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def screen_scores(X, theta, col_norm, r, *, bn=512, bp=256,
                  interpret: bool | None = None):
    """SAIF screening scan: (score, ub, lb) per feature."""
    if interpret is None:
        interpret = not on_tpu()
    return screen_scores_pallas(X, theta, col_norm, r, bn=bn, bp=bp,
                                interpret=interpret)


def cm_epochs(A, y, beta, col_sq, mask, lam, *, n_epochs=1,
              interpret: bool | None = None):
    """VMEM-resident cyclic CM sweeps (least squares)."""
    if interpret is None:
        interpret = not on_tpu()
    return cm_epochs_pallas(A, y, beta, col_sq, mask, lam,
                            n_epochs=n_epochs, interpret=interpret)


__all__ = ["screen_scores", "cm_epochs", "screen_scores_ref",
           "cm_epochs_ref", "on_tpu"]
