"""Pallas TPU kernel: the chain-graph Theorem-6 column transform.

For the 1-D fused LASSO (path graph 0-1-...-p-1 rooted at 0) the subtree
below the edge into node v is exactly {v, v+1, ..., p-1}, so the whole
Theorem-6 transform collapses to the *suffix sums* of the design columns:

    S[:, v] = sum_{u >= v} X[:, u]
    x_tilde_e = S[:, e+1]          (edge e's transformed column)
    x_b       = S[:, 0]            (the unpenalized b column)

TPU mapping: grid = (p/BP,), tiles visited RIGHT to LEFT (the index map
reverses the program id — TPU grids execute sequentially, so the (n,)-
shaped running carry can live in an output block with a constant index map
that every step revisits, the same accumulation pattern as the screening
kernels). Inside a tile the suffix is an exact *right fold*
(acc = x[:, l] + acc, one IEEE add per column): bitwise-identical to the
dense numpy reference ``repro.core.fused.transform_design``, which is what
the device-transform parity suite asserts. A triangular-matmul form would
feed the MXU but re-associates the sums; the transform runs once per fused
problem, so the exact fold wins (DESIGN.md §7).

Execution mode: ``interpret=None`` auto-detects like every other kernel in
``repro.kernels`` — compiled Mosaic on TPU, interpreter fallback on CPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.screen.screen import default_interpret

# the (n_pad, bp) tile + its output + the (n_pad,) carry, double-buffered
FUSED_VMEM_BUDGET_BYTES = 8 * 1024 * 1024


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def autotune_chain_block(n: int, p: int, *, dtype_bytes: int = 4) -> int:
    """Lane-dim tile width bp for the suffix-sum kernel (multiple of 128),
    shrunk until in+out tiles fit the VMEM budget at this n."""
    n_pad = _round_up(max(n, 1), 8)
    bp = min(512, _round_up(max(p, 1), 128))
    while bp > 128 and 2 * n_pad * bp * dtype_bytes > FUSED_VMEM_BUDGET_BYTES:
        bp //= 2
    return bp


def _chain_suffix_kernel(x_ref, s_ref, tot_ref, *, bp: int):
    i = pl.program_id(0)        # i-th tile from the RIGHT (index map flips)

    @pl.when(i == 0)
    def _init():
        tot_ref[...] = jnp.zeros_like(tot_ref)

    x = x_ref[...]              # (n_pad, bp)
    carry = tot_ref[...]        # (n_pad,) suffix total of all tiles right

    def fold(jj, state):
        acc, out = state
        l = bp - 1 - jj
        acc = x[:, l] + acc     # ONE IEEE add per column: exact right fold
        out = jax.lax.dynamic_update_index_in_dim(out, acc, l, 1)
        return acc, out

    acc, out = jax.lax.fori_loop(0, bp, fold, (carry, jnp.zeros_like(x)))
    s_ref[...] = out
    tot_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("bp", "interpret"))
def chain_suffix_sums_pallas(X, *, bp: int | None = None,
                             interpret: bool | None = None):
    """Suffix sums S[:, v] = sum_{u >= v} X[:, u] of the design columns.

    Computation runs in X.dtype (f32 on TPU, f64 under the x64
    interpreter); the fold order matches the dense numpy reference exactly
    (see the module docstring), so the parity tests compare bitwise.
    """
    n, p = X.shape
    dt = X.dtype
    if bp is None:
        bp = autotune_chain_block(n, p, dtype_bytes=dt.itemsize)
    if interpret is None:
        interpret = default_interpret()
    n_pad = -n % 8
    p_pad = -p % bp
    # rows pad with zeros (sliced off); columns pad on the RIGHT with
    # zeros — a zero column leaves the right fold bitwise unchanged
    Xp = jnp.pad(X, ((0, n_pad), (0, p_pad)))
    np_, pp = Xp.shape
    p_blocks = pp // bp
    kernel = functools.partial(_chain_suffix_kernel, bp=bp)
    S, _ = pl.pallas_call(
        kernel,
        grid=(p_blocks,),
        in_specs=[
            # visit tiles right-to-left so the carry always holds the
            # completed suffix of everything to the right
            pl.BlockSpec((np_, bp), lambda i: (0, p_blocks - 1 - i)),
        ],
        out_specs=[
            pl.BlockSpec((np_, bp), lambda i: (0, p_blocks - 1 - i)),
            pl.BlockSpec((np_,), lambda i: (0,)),   # carry (revisited)
        ],
        out_shape=[
            jax.ShapeDtypeStruct((np_, pp), dt),    # S
            jax.ShapeDtypeStruct((np_,), dt),       # running total
        ],
        interpret=interpret,
    )(Xp)
    return S[:n, :p]


def chain_suffix_sums_ref(X):
    """Dense jnp reference: the same exact right fold, no tiling."""
    X = jnp.asarray(X)
    n, p = X.shape

    def fold(jj, S):
        v = p - 2 - jj
        return S.at[:, v].set(X[:, v] + S[:, v + 1])

    S0 = jnp.zeros_like(X).at[:, p - 1].set(X[:, p - 1])
    return jax.lax.fori_loop(0, p - 1, fold, S0)
