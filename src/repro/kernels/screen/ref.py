"""Pure-jnp oracles for the screening kernels."""
import jax
import jax.numpy as jnp


def screen_scores_ref(X, theta, col_norm, r):
    """score = |X^T theta|, ub = score + ||x||r, lb = |score - ||x||r|."""
    score = jnp.abs(X.T @ theta)
    nr = col_norm * r
    return score, score + nr, jnp.abs(score - nr)


def screen_fused_ref(X, theta, col_norm, active, r, *, h: int):
    """Oracle for the fused ADD-phase scan.

    Returns (score, ub, lb, top_s, top_i, max_ub) with active features
    masked to score = ub = -inf exactly as the kernel does.
    """
    score = jnp.abs(X.T @ theta)
    nr = col_norm * r
    masked = jnp.where(jnp.asarray(active, bool), -jnp.inf, score)
    ub = masked + nr
    lb = jnp.abs(masked - nr)
    top_s, top_i = jax.lax.top_k(masked, h)
    return masked, ub, lb, top_s, top_i.astype(jnp.int32), jnp.max(ub)


def ub_histogram_ref(ub, lb_sorted):
    """bincount(searchsorted(lb_sorted, ub, 'right'), length=h+1)."""
    h = lb_sorted.shape[0]
    c = jnp.searchsorted(lb_sorted, ub, side="right")
    return jnp.zeros((h + 1,), jnp.int32).at[c].add(1)
