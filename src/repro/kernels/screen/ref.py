"""Pure-jnp oracle for the screening scan kernel."""
import jax.numpy as jnp


def screen_scores_ref(X, theta, col_norm, r):
    """score = |X^T theta|, ub = score + ||x||r, lb = |score - ||x||r|."""
    score = jnp.abs(X.T @ theta)
    nr = col_norm * r
    return score, score + nr, jnp.abs(score - nr)
