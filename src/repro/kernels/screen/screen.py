"""Pallas TPU kernels: the SAIF screening scan (the only O(p) hot spot).

Two kernels:

``screen_scores_pallas`` — the plain scan. For every feature column x_i of
X (n x p):
    score_i = |x_i^T theta|
    ub_i    = score_i + ||x_i|| * r      (ADD-stop / DEL upper bound)
    lb_i    = | score_i - ||x_i|| * r |  (ADD violation lower bound)

``screen_fused_pallas`` — the compile-first ADD-phase scan. Same quantities,
plus everything the solver's ADD decision needs so no second full-width pass
(and in particular no O(p log p) sort) happens outside the kernel:
    * the active-set exclusion mask is applied in-kernel (excluded features
      get score = ub = -inf, lb = +inf, i.e. never recruitable),
    * each p-tile emits its local top-h (score, global id) candidates —
      the global top-h is a cheap O((p/bp) h) merge of tile winners,
    * each p-tile emits its local max ub — the ADD-stop reduction.

``ub_histogram_pallas`` — the violation-count reduction. Given the (p,) ub
vector and the h sorted candidate lower bounds, emits the exact histogram
hist[m] = #{i : m lower bounds <= ub_i}; suffix sums of this histogram are
the per-candidate violation counts |V_l| = #{i in R_t : ub_i >= lb_l}. This
replaces the former full-vector ``jnp.sort`` + ``searchsorted`` (O(p log p))
with an O(p h / lanes) streaming compare — identical integers, bit for bit.

TPU mapping: grid = (p/BP, n/BN). Each instance streams an (BN, BP) tile of X
HBM->VMEM, does the MXU-friendly partial matvec theta_tile @ X_tile, and
accumulates into the (BP,)-shaped output block (output index map is constant
along the n axis, so the same VMEM block is revisited across the inner grid
dim — TPU grids execute sequentially, making this a safe accumulation).
On the last n-step the raw dot is finalized.

Execution mode: ``interpret=None`` auto-detects — compiled Mosaic on a TPU
backend, interpreter fallback elsewhere (this container is CPU-only; the
interpreter executes the kernel body in Python for correctness validation).

Block shapes: ``autotune_screen_blocks`` picks (BN, BP) from (n, p) under a
VMEM budget — lane dim a multiple of 128 for the MXU/VPU, sublane a multiple
of 8 (f32), X tile capped so HBM->VMEM double buffering fits comfortably in
the ~16 MB v5e budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BN = 512
DEFAULT_BP = 256

# X-tile budget: ~1/4 of a 16 MB VMEM so the pipeline can double-buffer the
# big operand and still hold the (BP,)-shaped accumulators + candidate state.
VMEM_TILE_BUDGET_BYTES = 4 * 1024 * 1024


def default_interpret() -> bool:
    """Compiled Mosaic on TPU, interpreter everywhere else (CPU fallback)."""
    return jax.default_backend() != "tpu"


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def autotune_screen_blocks(n: int, p: int, *, dtype_bytes: int = 4,
                           vmem_budget_bytes: int = VMEM_TILE_BUDGET_BYTES,
                           batch: int = 1) -> tuple:
    """Pick (bn, bp) for the screening kernels from the problem shape.

    bp (lane dim) is a multiple of 128, bn (sublane dim) a multiple of 8;
    both are clipped to the padded problem so tiny problems run one tile,
    and bn shrinks (keeping the wide lane dim) until a double-buffered X
    tile fits the VMEM budget.

    ``batch`` > 1 is the problem-gridded fleet kernel (DESIGN.md §8): the
    X tile is revisited across the fleet's grid axis, so it must coexist
    in VMEM with one problem's (bn,)/(bp,)-shaped vector blocks *per
    in-flight problem* — the budget is charged for the double-buffered
    vector working set of two problems in addition to the X tile.
    """
    bp = min(512, _round_up(max(p, 1), 128))
    bn = min(DEFAULT_BN, _round_up(max(n, 1), 8))
    vec_bytes = (2 * (bn + 4 * bp) * dtype_bytes) if batch > 1 else 0
    while bn > 8 and 2 * bn * bp * dtype_bytes + vec_bytes > \
            vmem_budget_bytes:
        bn = max(8, _round_up(bn // 2, 8))
        vec_bytes = (2 * (bn + 4 * bp) * dtype_bytes) if batch > 1 else 0
    return bn, bp


# --------------------------------------------------------------------------
# plain scan kernel (score, ub, lb)
# --------------------------------------------------------------------------

def _screen_kernel(theta_ref, x_ref, norm_ref, r_ref,
                   score_ref, ub_ref, lb_ref, *, n_blocks: int):
    j = pl.program_id(1)                     # n-axis step

    @pl.when(j == 0)
    def _init():
        score_ref[...] = jnp.zeros_like(score_ref)

    # partial matvec: (BN,) @ (BN, BP) -> (BP,)
    partial = jnp.dot(theta_ref[...], x_ref[...],
                      preferred_element_type=jnp.float32)
    score_ref[...] += partial

    @pl.when(j == n_blocks - 1)
    def _finalize():
        raw = score_ref[...]
        s = jnp.abs(raw)
        nr = norm_ref[...] * r_ref[0]
        score_ref[...] = s
        ub_ref[...] = s + nr
        lb_ref[...] = jnp.abs(s - nr)


@functools.partial(jax.jit,
                   static_argnames=("bn", "bp", "interpret"))
def screen_scores_pallas(X, theta, col_norm, r, *,
                         bn: int | None = None, bp: int | None = None,
                         interpret: bool | None = None):
    """Blocked screening scan. X: (n, p) f32; returns (score, ub, lb) (p,).

    Padding: n and p are padded up to block multiples with zeros — zero
    columns produce score 0, ub = 0 + 0*r, harmless and sliced off.
    """
    n, p = X.shape
    if bn is None or bp is None:
        abn, abp = autotune_screen_blocks(n, p)
        bn = bn or abn
        bp = bp or abp
    if interpret is None:
        interpret = default_interpret()
    n_pad = -n % bn
    p_pad = -p % bp
    Xp = jnp.pad(X.astype(jnp.float32), ((0, n_pad), (0, p_pad)))
    theta_p = jnp.pad(theta.astype(jnp.float32), (0, n_pad))
    norm_p = jnp.pad(col_norm.astype(jnp.float32), (0, p_pad))
    np_, pp = Xp.shape
    n_blocks, p_blocks = np_ // bn, pp // bp
    r_arr = jnp.asarray(r, jnp.float32).reshape(1)

    out_shape = [jax.ShapeDtypeStruct((pp,), jnp.float32)] * 3
    grid = (p_blocks, n_blocks)
    kernel = functools.partial(_screen_kernel, n_blocks=n_blocks)
    score, ub, lb = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn,), lambda i, j: (j,)),          # theta
            pl.BlockSpec((bn, bp), lambda i, j: (j, i)),     # X tile
            pl.BlockSpec((bp,), lambda i, j: (i,)),          # col_norm
            pl.BlockSpec((1,), lambda i, j: (0,)),           # r
        ],
        out_specs=[
            pl.BlockSpec((bp,), lambda i, j: (i,)),          # score
            pl.BlockSpec((bp,), lambda i, j: (i,)),          # ub
            pl.BlockSpec((bp,), lambda i, j: (i,)),          # lb
        ],
        out_shape=out_shape,
        interpret=interpret,
    )(theta_p, Xp, norm_p, r_arr)
    return score[:p], ub[:p], lb[:p]


# --------------------------------------------------------------------------
# fused ADD-phase kernel (masked score/ub/lb + tile top-h + tile max-ub)
# --------------------------------------------------------------------------

def _tile_top_h(masked_scores, lanes, h_tile: int):
    """Iterative max-extraction top-h of a (BP,) tile.

    O(h * BP) VPU work per tile — negligible next to the BN x BP matvec.
    Ties break to the smallest lane index, matching ``jax.lax.top_k``'s
    stable order, so the tile-merge reduction downstream reproduces a
    global top_k exactly on every finite candidate. An explicit
    availability mask (not value re-masking) keeps the emitted lane ids
    distinct even once a tile's finite entries are exhausted and only
    -inf (masked/padding) lanes remain; those -inf ids are never
    recruited downstream (keep &= isfinite), and in a deeply saturated
    tile their order may differ from a global top_k's -inf tail — the
    only regime where the merge is not literally top_k. (Sort-free on
    purpose: no O(p log p) anywhere.)
    """
    neg = jnp.asarray(-jnp.inf, masked_scores.dtype)
    bp = masked_scores.shape[0]

    def body(t, carry):
        avail, ts, ti = carry
        vals = jnp.where(avail, masked_scores, neg)
        m = jnp.max(vals)
        i = jnp.min(jnp.where(avail & (vals == m), lanes, bp)).astype(
            jnp.int32)
        ts = jax.lax.dynamic_update_index_in_dim(ts, m, t, 0)
        ti = jax.lax.dynamic_update_index_in_dim(ti, i, t, 0)
        avail = avail & (lanes != i)
        return avail, ts, ti

    # h_tile <= bp, so an available lane always exists at every step
    init = (jnp.ones((bp,), bool),
            jnp.full((h_tile,), neg, masked_scores.dtype),
            jnp.zeros((h_tile,), jnp.int32))
    _, ts, ti = jax.lax.fori_loop(0, h_tile, body, init)
    return ts, ti


def _screen_fused_kernel(theta_ref, x_ref, norm_ref, act_ref, r_ref,
                         score_ref, ub_ref, lb_ref,
                         tops_ref, topi_ref, tmax_ref,
                         *, n_blocks: int, h_tile: int, bp: int):
    i = pl.program_id(0)                     # p-axis tile (for global ids)
    j = pl.program_id(1)                     # n-axis step

    @pl.when(j == 0)
    def _init():
        score_ref[...] = jnp.zeros_like(score_ref)

    partial = jnp.dot(theta_ref[...], x_ref[...],
                      preferred_element_type=score_ref.dtype)
    score_ref[...] += partial

    @pl.when(j == n_blocks - 1)
    def _finalize():
        raw = score_ref[...]
        s = jnp.abs(raw)
        nr = norm_ref[...] * r_ref[0]
        neg = jnp.asarray(-jnp.inf, s.dtype)
        # active (or padding) features are not recruitable: score/ub -> -inf
        ms = jnp.where(act_ref[...] > 0.5, neg, s)
        ub = ms + nr
        score_ref[...] = ms
        ub_ref[...] = ub
        lb_ref[...] = jnp.abs(ms - nr)
        tmax_ref[0] = jnp.max(ub)
        lanes = jax.lax.broadcasted_iota(jnp.int32, (bp,), 0)
        ts, ti = _tile_top_h(ms, lanes, h_tile)
        tops_ref[0, :] = ts
        topi_ref[0, :] = ti + i * bp                  # global feature ids


def _screen_dtypes(X, in_dtype, acc_dtype):
    """Resolve the (input, accumulator) dtype pair for a screening kernel.

    ``in_dtype`` (e.g. "bfloat16") is the dtype the X / theta tiles are
    cast to before the MXU dot; ``acc_dtype`` is the accumulator and
    output dtype (defaults to f32 when the input is low precision — the
    MXU accumulates bf16 x bf16 into f32 natively via
    preferred_element_type). The certified rounding bound for the pair is
    ``duality.mixed_precision_gamma(n, in_dtype, acc_dtype)``; widening
    the radius by it happens in the CALLER (screen_backend), the kernel
    just computes in the requested precisions.
    """
    dt_in = X.dtype if in_dtype is None else jnp.dtype(in_dtype)
    if acc_dtype is not None:
        dt_acc = jnp.dtype(acc_dtype)
    elif dt_in == X.dtype:
        dt_acc = X.dtype
    else:
        dt_acc = jnp.promote_types(jnp.float32, dt_in)
    return dt_in, dt_acc


@functools.partial(jax.jit,
                   static_argnames=("h", "bn", "bp", "interpret",
                                    "in_dtype", "acc_dtype"))
def screen_fused_pallas(X, theta, col_norm, active, r, *, h: int,
                        bn: int | None = None, bp: int | None = None,
                        interpret: bool | None = None,
                        in_dtype: str | None = None,
                        acc_dtype: str | None = None):
    """Fused ADD-phase scan.

    Args:
      X:        (n, p) design (any float dtype; compute stays in X.dtype
                unless ``in_dtype``/``acc_dtype`` request a mixed-
                precision pass — see :func:`_screen_dtypes`).
      theta:    (n,) dual ball center.
      col_norm: (p,) column norms.
      active:   (p,) bool/0-1 mask of features to EXCLUDE (current actives).
      r:        scalar ball radius.
      h:        static per-tile candidate count.

    Returns (all padding sliced/neutralized):
      score (p,), ub (p,), lb (p,)           — masked quantities,
      tile_top_s (p_blocks, min(h, bp))       — tile-local top-h scores,
      tile_top_i (p_blocks, min(h, bp)) int32 — their global feature ids,
      tile_max_ub (p_blocks,)                 — tile-local max ub.
    """
    n, p = X.shape
    dt_in, dt_acc = _screen_dtypes(X, in_dtype, acc_dtype)
    if bn is None or bp is None:
        abn, abp = autotune_screen_blocks(n, p,
                                          dtype_bytes=dt_in.itemsize)
        bn = bn or abn
        bp = bp or abp
    if dt_in.itemsize == 2:
        bn = _round_up(bn, 16)       # bf16 sublane tile is 16, not 8
    if interpret is None:
        interpret = default_interpret()
    h_tile = max(1, min(h, bp))
    dt = dt_acc
    n_pad = -n % bn
    p_pad = -p % bp
    Xp = jnp.pad(X.astype(dt_in), ((0, n_pad), (0, p_pad)))
    theta_p = jnp.pad(theta.astype(dt_in), (0, n_pad))
    norm_p = jnp.pad(col_norm.astype(dt), (0, p_pad))
    # padding columns are flagged "active" => excluded from recruitment
    act_p = jnp.pad(jnp.asarray(active).astype(dt), (0, p_pad),
                    constant_values=1.0)
    np_, pp = Xp.shape
    n_blocks, p_blocks = np_ // bn, pp // bp
    r_arr = jnp.asarray(r, dt).reshape(1)

    out_shape = [
        jax.ShapeDtypeStruct((pp,), dt),                 # score
        jax.ShapeDtypeStruct((pp,), dt),                 # ub
        jax.ShapeDtypeStruct((pp,), dt),                 # lb
        jax.ShapeDtypeStruct((p_blocks, h_tile), dt),    # tile top scores
        jax.ShapeDtypeStruct((p_blocks, h_tile), jnp.int32),
        jax.ShapeDtypeStruct((p_blocks,), dt),           # tile max ub
    ]
    grid = (p_blocks, n_blocks)
    kernel = functools.partial(_screen_fused_kernel, n_blocks=n_blocks,
                               h_tile=h_tile, bp=bp)
    vec = pl.BlockSpec((bp,), lambda i, j: (i,))
    score, ub, lb, tops, topi, tmax = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn,), lambda i, j: (j,)),          # theta
            pl.BlockSpec((bn, bp), lambda i, j: (j, i)),     # X tile
            vec,                                             # col_norm
            vec,                                             # active mask
            pl.BlockSpec((1,), lambda i, j: (0,)),           # r
        ],
        out_specs=[
            vec, vec, vec,                                   # score, ub, lb
            pl.BlockSpec((1, h_tile), lambda i, j: (i, 0)),  # tile top s
            pl.BlockSpec((1, h_tile), lambda i, j: (i, 0)),  # tile top ids
            pl.BlockSpec((1,), lambda i, j: (i,)),           # tile max ub
        ],
        out_shape=out_shape,
        interpret=interpret,
    )(theta_p, Xp, norm_p, act_p, r_arr)
    return score[:p], ub[:p], lb[:p], tops, topi, tmax


# --------------------------------------------------------------------------
# problem-gridded fused ADD-phase kernel (batch fleets, DESIGN.md §8)
# --------------------------------------------------------------------------

def _screen_fused_batch_kernel(theta_ref, x_ref, norm_ref, act_ref, r_ref,
                               score_ref, ub_ref, lb_ref,
                               tops_ref, topi_ref, tmax_ref,
                               *, n_blocks: int, h_tile: int, bp: int):
    i = pl.program_id(0)                     # p-axis tile (for global ids)
    j = pl.program_id(2)                     # n-axis step (innermost)

    @pl.when(j == 0)
    def _init():
        score_ref[...] = jnp.zeros_like(score_ref)

    # partial matvec for THIS problem's theta against the SHARED X tile
    partial = jnp.dot(theta_ref[0, :], x_ref[...],
                      preferred_element_type=score_ref.dtype)
    score_ref[0, :] += partial

    @pl.when(j == n_blocks - 1)
    def _finalize():
        raw = score_ref[0, :]
        s = jnp.abs(raw)
        nr = norm_ref[0, :] * r_ref[0]
        neg = jnp.asarray(-jnp.inf, s.dtype)
        ms = jnp.where(act_ref[0, :] > 0.5, neg, s)
        ub = ms + nr
        score_ref[0, :] = ms
        ub_ref[0, :] = ub
        lb_ref[0, :] = jnp.abs(ms - nr)
        tmax_ref[0, 0] = jnp.max(ub)
        lanes = jax.lax.broadcasted_iota(jnp.int32, (bp,), 0)
        ts, ti = _tile_top_h(ms, lanes, h_tile)
        tops_ref[0, 0, :] = ts
        topi_ref[0, 0, :] = ti + i * bp                  # global feature ids


@functools.partial(jax.jit,
                   static_argnames=("h", "bn", "bp", "interpret",
                                    "in_dtype", "acc_dtype"))
def screen_fused_batch_pallas(X, Theta, col_norm, active, r, *, h: int,
                              bn: int | None = None, bp: int | None = None,
                              interpret: bool | None = None,
                              in_dtype: str | None = None,
                              acc_dtype: str | None = None):
    """Fleet ADD-phase scan: one launch screens all B problems.

    Same per-problem math as :func:`screen_fused_pallas`, with a grid axis
    over problems. Grid order is (p-tiles, problems, n-steps): the n-axis
    stays innermost so the per-(problem, p-tile) score accumulator is
    revisited consecutively (the TPU sequential-grid contract), and
    whenever the sample dim fits one tile (n <= bn — the SAIF norm) the
    shared X tile's index map is constant across the problem axis, so the
    VMEM-resident design block is fetched once and reused by the whole
    fleet — the shared-X fast path. Distinct-X fleets don't use this
    kernel; they take the einsum fallback in ``core/screen_backend.py``.

    Args:
      X:        (n, p) SHARED design.
      Theta:    (B, n) per-problem dual ball centers.
      col_norm: (B, p) per-problem column norms (CV fleets differ per
                problem; multi-response fleets broadcast one row).
      active:   (B, p) per-problem exclusion masks.
      r:        (B,) per-problem ball radii.

    Returns (score, ub, lb) as (B, p) plus tile winners
    (B, p_blocks, h_tile) x2 and tile max-ub (B, p_blocks).

    ``in_dtype``/``acc_dtype`` select a mixed-precision pass (e.g. bf16
    tiles, f32 accumulation — :func:`_screen_dtypes`): X/Theta tiles are
    cast to ``in_dtype``, the dot accumulates and every emitted quantity
    is in ``acc_dtype``. Halving the tile bytes doubles the design rows
    per VMEM fetch — the fleet's shared-X read amortization improves by
    the same factor. Callers certify the precision with the widened
    radius (DESIGN.md §11); this kernel only changes dtypes, not rules.
    """
    n, p = X.shape
    b = Theta.shape[0]
    dt_in, dt_acc = _screen_dtypes(X, in_dtype, acc_dtype)
    if bn is None or bp is None:
        abn, abp = autotune_screen_blocks(n, p,
                                          dtype_bytes=dt_in.itemsize,
                                          batch=b)
        bn = bn or abn
        bp = bp or abp
    if dt_in.itemsize == 2:
        bn = _round_up(bn, 16)       # bf16 sublane tile is 16, not 8
    if interpret is None:
        interpret = default_interpret()
    h_tile = max(1, min(h, bp))
    dt = dt_acc
    n_pad = -n % bn
    p_pad = -p % bp
    Xp = jnp.pad(X.astype(dt_in), ((0, n_pad), (0, p_pad)))
    theta_p = jnp.pad(Theta.astype(dt_in), ((0, 0), (0, n_pad)))
    norm_p = jnp.pad(col_norm.astype(dt), ((0, 0), (0, p_pad)))
    act_p = jnp.pad(jnp.asarray(active).astype(dt), ((0, 0), (0, p_pad)),
                    constant_values=1.0)
    np_, pp = Xp.shape
    n_blocks, p_blocks = np_ // bn, pp // bp
    r_arr = jnp.asarray(r, dt)

    out_shape = [
        jax.ShapeDtypeStruct((b, pp), dt),                 # score
        jax.ShapeDtypeStruct((b, pp), dt),                 # ub
        jax.ShapeDtypeStruct((b, pp), dt),                 # lb
        jax.ShapeDtypeStruct((b, p_blocks, h_tile), dt),   # tile top scores
        jax.ShapeDtypeStruct((b, p_blocks, h_tile), jnp.int32),
        jax.ShapeDtypeStruct((b, p_blocks), dt),           # tile max ub
    ]
    grid = (p_blocks, b, n_blocks)
    kernel = functools.partial(_screen_fused_batch_kernel,
                               n_blocks=n_blocks, h_tile=h_tile, bp=bp)
    vec = pl.BlockSpec((1, bp), lambda i, bb, j: (bb, i))
    score, ub, lb, tops, topi, tmax = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bn), lambda i, bb, j: (bb, j)),   # theta
            pl.BlockSpec((bn, bp), lambda i, bb, j: (j, i)),   # shared X
            vec,                                               # col_norm
            vec,                                               # active mask
            pl.BlockSpec((1,), lambda i, bb, j: (bb,)),        # r
        ],
        out_specs=[
            vec, vec, vec,                                     # score/ub/lb
            pl.BlockSpec((1, 1, h_tile), lambda i, bb, j: (bb, i, 0)),
            pl.BlockSpec((1, 1, h_tile), lambda i, bb, j: (bb, i, 0)),
            pl.BlockSpec((1, 1), lambda i, bb, j: (bb, i)),    # tile max ub
        ],
        out_shape=out_shape,
        interpret=interpret,
    )(theta_p, Xp, norm_p, act_p, r_arr)
    return (score[:, :p], ub[:, :p], lb[:, :p], tops, topi, tmax)


# --------------------------------------------------------------------------
# violation-count histogram kernel
# --------------------------------------------------------------------------

def _ub_hist_kernel(ub_ref, lb_ref, hist_ref, *, n_bins: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)

    ub = ub_ref[...]                                     # (bp,)
    lb = lb_ref[...]                                     # (h,)
    # c_i = #{l : lb_sorted[l] <= ub_i}  (exact searchsorted-right count)
    c = jnp.sum((lb[None, :] <= ub[:, None]).astype(jnp.int32), axis=1,
                dtype=jnp.int32)
    bins = jax.lax.broadcasted_iota(jnp.int32, (ub.shape[0], n_bins), 1)
    hist_ref[...] += jnp.sum((c[:, None] == bins).astype(jnp.int32), axis=0,
                             dtype=jnp.int32)


@functools.partial(jax.jit, static_argnames=("bp", "interpret"))
def ub_histogram_pallas(ub, lb_sorted, *, bp: int | None = None,
                        interpret: bool | None = None):
    """Histogram of c_i = #{l : lb_sorted[l] <= ub_i} over bins 0..h.

    Exactly ``bincount(searchsorted(lb_sorted, ub, 'right'), length=h+1)``,
    streamed tile by tile. Suffix sums give the per-candidate counts
    #{i : ub_i >= lb_sorted[j]} without ever sorting the (p,) vector.
    """
    (p,) = ub.shape
    h = lb_sorted.shape[0]
    if bp is None:
        bp = min(2048, _round_up(max(p, 1), 128))
    if interpret is None:
        interpret = default_interpret()
    # pad with -inf => c = 0 => only bin 0 (never used by suffix sums) grows
    ub_p = jnp.pad(ub, (0, -p % bp), constant_values=-jnp.inf)
    p_blocks = ub_p.shape[0] // bp
    n_bins = h + 1
    kernel = functools.partial(_ub_hist_kernel, n_bins=n_bins)
    hist = pl.pallas_call(
        kernel,
        grid=(p_blocks,),
        in_specs=[
            pl.BlockSpec((bp,), lambda i: (i,)),             # ub tile
            pl.BlockSpec((h,), lambda i: (0,)),              # lb (replicated)
        ],
        out_specs=pl.BlockSpec((n_bins,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((n_bins,), jnp.int32),
        interpret=interpret,
    )(ub_p, lb_sorted)
    return hist


def _ub_hist_batch_kernel(ub_ref, lb_ref, hist_ref, *, n_bins: int):
    i = pl.program_id(1)                                 # p-tile (innermost)

    @pl.when(i == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)

    ub = ub_ref[0, :]                                    # (bp,)
    lb = lb_ref[0, :]                                    # (h,)
    c = jnp.sum((lb[None, :] <= ub[:, None]).astype(jnp.int32), axis=1,
                dtype=jnp.int32)
    bins = jax.lax.broadcasted_iota(jnp.int32, (ub.shape[0], n_bins), 1)
    hist_ref[0, :] += jnp.sum((c[:, None] == bins).astype(jnp.int32),
                              axis=0, dtype=jnp.int32)


@functools.partial(jax.jit, static_argnames=("bp", "interpret"))
def ub_histogram_batch_pallas(ub, lb_sorted, *, bp: int | None = None,
                              interpret: bool | None = None):
    """Per-problem :func:`ub_histogram_pallas`: ub (B, p), lb_sorted (B, h)
    -> hist (B, h+1). Grid = (problems, p-tiles) with the tile axis
    innermost so each problem's histogram block accumulates consecutively.
    """
    b, p = ub.shape
    h = lb_sorted.shape[1]
    if bp is None:
        bp = min(2048, _round_up(max(p, 1), 128))
    if interpret is None:
        interpret = default_interpret()
    ub_p = jnp.pad(ub, ((0, 0), (0, -p % bp)), constant_values=-jnp.inf)
    p_blocks = ub_p.shape[1] // bp
    n_bins = h + 1
    kernel = functools.partial(_ub_hist_batch_kernel, n_bins=n_bins)
    hist = pl.pallas_call(
        kernel,
        grid=(b, p_blocks),
        in_specs=[
            pl.BlockSpec((1, bp), lambda bb, i: (bb, i)),    # ub tile
            pl.BlockSpec((1, h), lambda bb, i: (bb, 0)),     # lb row
        ],
        out_specs=pl.BlockSpec((1, n_bins), lambda bb, i: (bb, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n_bins), jnp.int32),
        interpret=interpret,
    )(ub_p, lb_sorted)
    return hist
