"""Pallas TPU kernel: the SAIF screening scan (the only O(p) hot spot).

Computes, for every feature column x_i of X (n x p):
    score_i = |x_i^T theta|
    ub_i    = score_i + ||x_i|| * r      (ADD-stop / DEL upper bound)
    lb_i    = | score_i - ||x_i|| * r |  (ADD violation lower bound)

TPU mapping: grid = (p/BP, n/BN). Each instance streams an (BN, BP) tile of X
HBM->VMEM, does the MXU-friendly partial matvec theta_tile @ X_tile, and
accumulates into the (BP,)-shaped output block (output index map is constant
along the n axis, so the same VMEM block is revisited across the inner grid
dim — TPU grids execute sequentially, making this a safe accumulation).
On the last n-step the raw dot is finalized into (score, ub, lb).

Block shapes default to BN=512, BP=256: X tile 512x256 f32 = 512 KB VMEM,
well under the ~16 MB v5e budget while keeping the lane dim a multiple of 128
for the MXU/VPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BN = 512
DEFAULT_BP = 256


def _screen_kernel(theta_ref, x_ref, norm_ref, r_ref,
                   score_ref, ub_ref, lb_ref, *, n_blocks: int):
    j = pl.program_id(1)                     # n-axis step

    @pl.when(j == 0)
    def _init():
        score_ref[...] = jnp.zeros_like(score_ref)

    # partial matvec: (BN,) @ (BN, BP) -> (BP,)
    partial = jnp.dot(theta_ref[...], x_ref[...],
                      preferred_element_type=jnp.float32)
    score_ref[...] += partial

    @pl.when(j == n_blocks - 1)
    def _finalize():
        raw = score_ref[...]
        s = jnp.abs(raw)
        nr = norm_ref[...] * r_ref[0]
        score_ref[...] = s
        ub_ref[...] = s + nr
        lb_ref[...] = jnp.abs(s - nr)


@functools.partial(jax.jit,
                   static_argnames=("bn", "bp", "interpret"))
def screen_scores_pallas(X, theta, col_norm, r, *,
                         bn: int = DEFAULT_BN, bp: int = DEFAULT_BP,
                         interpret: bool = True):
    """Blocked screening scan. X: (n, p) f32; returns (score, ub, lb) (p,).

    Padding: n and p are padded up to block multiples with zeros — zero
    columns produce score 0, ub = 0 + 0*r, harmless and sliced off.
    """
    n, p = X.shape
    n_pad = -n % bn
    p_pad = -p % bp
    Xp = jnp.pad(X.astype(jnp.float32), ((0, n_pad), (0, p_pad)))
    theta_p = jnp.pad(theta.astype(jnp.float32), (0, n_pad))
    norm_p = jnp.pad(col_norm.astype(jnp.float32), (0, p_pad))
    np_, pp = Xp.shape
    n_blocks, p_blocks = np_ // bn, pp // bp
    r_arr = jnp.asarray(r, jnp.float32).reshape(1)

    out_shape = [jax.ShapeDtypeStruct((pp,), jnp.float32)] * 3
    grid = (p_blocks, n_blocks)
    kernel = functools.partial(_screen_kernel, n_blocks=n_blocks)
    score, ub, lb = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn,), lambda i, j: (j,)),          # theta
            pl.BlockSpec((bn, bp), lambda i, j: (j, i)),     # X tile
            pl.BlockSpec((bp,), lambda i, j: (i,)),          # col_norm
            pl.BlockSpec((1,), lambda i, j: (0,)),           # r
        ],
        out_specs=[
            pl.BlockSpec((bp,), lambda i, j: (i,)),          # score
            pl.BlockSpec((bp,), lambda i, j: (i,)),          # ub
            pl.BlockSpec((bp,), lambda i, j: (i,)),          # lb
        ],
        out_shape=out_shape,
        interpret=interpret,
    )(theta_p, Xp, norm_p, r_arr)
    return score[:p], ub[:p], lb[:p]
